"""llama-3.2-vision-90b — VLM decoder with cross-attention image layers.

[hf:meta-llama/Llama-3.2-11B-Vision, 90b dims as assigned] 100L total,
d_model 8192, 64 heads (GQA kv=8), d_ff 28672, vocab 128256; every 5th
layer cross-attends to vision embeddings. The ViT encoder + projector is a
STUB: input_specs() provides projected patch embeddings (B, 1601, 8192).
"""
from repro.configs import base
from repro.configs.base import ArchConfig, ATTN, CROSS

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab=128256, pattern=(ATTN, ATTN, ATTN, ATTN, CROSS),
    encoder_seq=1601, cross_attn=True, rope_theta=500_000.0,
    sharding="fsdp", supports_long_500k=False,
    grad_accum=4,  # memory-term fit (EXPERIMENTS.md §Perf)
)

REDUCED = ArchConfig(
    name="llama-3.2-vision-90b-reduced", family="vlm", source=CONFIG.source,
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab=512, pattern=(ATTN, CROSS), encoder_seq=16, cross_attn=True,
    sharding="fsdp",
)

base.register(CONFIG, REDUCED)
