"""gemma2-9b — alternating local/global attention with logit soft-capping.

[arXiv:2408.00118] 42L, d_model 3584, 16 heads (GQA kv=8, head_dim 256),
d_ff 14336, vocab 256000, window 4096 on local layers, attn softcap 50,
final softcap 30, tied embeddings. long_500k runs natively: local layers
keep ring caches; the 21 global layers hold the full 500k cache (decode is
O(S)/step), sharded over the data axis.
"""
from repro.configs import base
from repro.configs.base import ArchConfig, ATTN, ATTN_LOCAL

CONFIG = ArchConfig(
    name="gemma2-9b", family="dense", source="arXiv:2408.00118",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, d_ff=14336,
    vocab=256000, head_dim=256, pattern=(ATTN_LOCAL, ATTN), window=4096,
    softcap=50.0, final_softcap=30.0, tie_embeddings=True,
    sharding="fsdp", supports_long_500k=True,
    grad_accum=2,  # memory-term fit (EXPERIMENTS.md §Perf)
)

REDUCED = ArchConfig(
    name="gemma2-9b-reduced", family="dense", source=CONFIG.source,
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab=512, head_dim=32, pattern=(ATTN_LOCAL, ATTN), window=32,
    softcap=50.0, final_softcap=30.0, tie_embeddings=True, sharding="fsdp",
)

base.register(CONFIG, REDUCED)
