"""grok-1-314b — large sparse MoE (8 experts, top-2), full attention.

[hf:xai-org/grok-1] 64L, d_model 6144, 48 heads (GQA kv=8), d_ff 32768,
vocab 131072. 314B params; fits one v5e pod only with FSDP + the
beyond-paper 8-bit Adam (quantized optimizer moments — the paper's memory
argument applied to training state). long_500k via the SWA variant.
"""
from repro.configs import base
from repro.configs.base import ArchConfig, MOE
from repro.core.qconfig import MixedPrecisionConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe", source="hf:xai-org/grok-1",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=32768,
    vocab=131072, pattern=(MOE,), n_experts=8, moe_top_k=2,
    sharding="fsdp", optimizer_8bit=True, supports_long_500k=False,
    grad_accum=4,  # 4 microbatches of 64 seqs: activation peak /4 (§Perf A3)
    # §Perf A4 (beyond-paper "fully quantized training state"): bf16 master
    # weights + bf16 grads + int8 Adam moments. Adam still updates in f32
    # transiently; 314B params drop from 4.9 GB/chip of fp32 master + 4.9 GB
    # grads to 2.45 + 2.45.
    mp=MixedPrecisionConfig(compute_dtype="bfloat16", param_dtype="bfloat16"),
)

REDUCED = ArchConfig(
    name="grok-1-314b-reduced", family="moe", source=CONFIG.source,
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab=512, pattern=(MOE,), n_experts=4, moe_top_k=2,
    sharding="fsdp", optimizer_8bit=True,
)

base.register(CONFIG, REDUCED)
