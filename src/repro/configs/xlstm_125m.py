"""xlstm-125m — alternating sLSTM + mLSTM blocks (attention-free).

[arXiv:2405.04517] 12L, d_model 768, 4 heads, d_ff 0 (the xLSTM cell has its
own internal projections; there is no separate FFN), vocab 50304.
Sub-quadratic by construction: O(1) recurrent state -> long_500k native.
"""
from repro.configs import base
from repro.configs.base import ArchConfig, MLSTM, SLSTM

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm", source="arXiv:2405.04517",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, pattern=(MLSTM, SLSTM), head_dim=192,
    sharding="tp", supports_long_500k=True,
)

REDUCED = ArchConfig(
    name="xlstm-125m-reduced", family="ssm", source=CONFIG.source,
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=512, pattern=(MLSTM, SLSTM), head_dim=32, sharding="tp",
)

base.register(CONFIG, REDUCED)
