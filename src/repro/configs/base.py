"""Architecture + input-shape registry.

Every assigned architecture gets one ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (the exact assigned dimensions, source cited) and the registry maps
``--arch <id>`` to it. ``reduced()`` derives the smoke-test variant
(2 layers, d_model <= 512, <= 4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

from repro.core.qconfig import QuantConfig, MixedPrecisionConfig

# Block kinds usable in a layer pattern.
ATTN = "attn"            # global self-attention
ATTN_LOCAL = "attn_local"  # sliding-window self-attention
MOE = "moe"              # attention + MoE ffn
MOE_LOCAL = "moe_local"  # sliding-window attention + MoE ffn
RGLRU = "rglru"          # RG-LRU recurrent block (griffin)
MLSTM = "mlstm"          # xLSTM matrix-memory block
SLSTM = "slstm"          # xLSTM scalar-memory block
CROSS = "cross"          # self-attn + cross-attn to modality embeddings


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    source: str                      # citation for the exact dims
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    pattern: Tuple[str, ...] = (ATTN,)   # repeating block-kind unit
    # MoE
    n_experts: int = 0
    moe_top_k: int = 2
    capacity_factor: float = 1.25
    # attention flavor
    window: Optional[int] = None         # sliding-window size for *_local
    softcap: Optional[float] = None      # gemma2 logit softcap
    final_softcap: Optional[float] = None
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm: str = "rms"                    # rms | layer
    activation: str = "silu"             # silu (SwiGLU) | gelu (GeGLU)
    # enc-dec / multimodal frontends (STUB: precomputed embeddings)
    encoder_layers: int = 0              # whisper audio encoder
    encoder_seq: int = 0                 # frames/patches provided by the stub
    cross_attn: bool = False             # consume encoder/vision embeddings
    # distribution
    sharding: str = "tp"                 # tp | fsdp
    remat: bool = True                   # activation checkpoint per block
    scan_layers: bool = True
    # training
    quant: QuantConfig = QuantConfig.none()
    mp: MixedPrecisionConfig = MixedPrecisionConfig.bf16()
    optimizer_8bit: bool = False         # beyond-paper: 8-bit Adam moments
    grad_accum: int = 1
    # decode
    long_context_window: Optional[int] = None  # SWA-variant for long_500k
    supports_long_500k: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pattern_repeats(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def pattern_remainder(self) -> Tuple[str, ...]:
        return tuple(self.pattern[: self.n_layers % len(self.pattern)])

    def n_params(self) -> int:
        """Analytic parameter count (for 6ND model-flops in the roofline)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv_heads
        total = v * d * (1 if self.tie_embeddings else 2)
        kinds = (list(self.pattern) * self.pattern_repeats
                 + list(self.pattern_remainder))
        for kind in kinds:
            attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
            if kind in (ATTN, ATTN_LOCAL):
                total += attn + 3 * d * f
            elif kind in (MOE, MOE_LOCAL):
                total += attn + self.n_experts * 3 * d * f + d * self.n_experts
            elif kind == RGLRU:
                total += 3 * d * (2 * d) + 2 * (2 * d)  # griffin block approx
            elif kind in (MLSTM, SLSTM):
                total += 8 * d * d
            elif kind == CROSS:
                total += 2 * attn + 3 * d * f
        total += self.encoder_layers * (4 * d * d + 3 * d * f)
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: top-k of the experts)."""
        if self.n_experts == 0:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        dense_total = self.n_params()
        kinds = (list(self.pattern) * self.pattern_repeats
                 + list(self.pattern_remainder))
        n_moe = sum(1 for k in kinds if k in (MOE, MOE_LOCAL))
        inactive = n_moe * (self.n_experts - self.moe_top_k) * 3 * d * f
        return dense_total - inactive


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: Dict[str, "ArchEntry"] = {}


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    config: ArchConfig
    reduced: ArchConfig


def register(config: ArchConfig, reduced: ArchConfig) -> ArchConfig:
    _REGISTRY[config.name] = ArchEntry(config, reduced)
    return config


def get(name: str) -> ArchConfig:
    _ensure_loaded()
    return _REGISTRY[name].config


def get_reduced(name: str) -> ArchConfig:
    _ensure_loaded()
    return _REGISTRY[name].reduced


def names() -> Sequence[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_ARCH_MODULES = [
    "h2o_danube_1_8b", "xlstm_125m", "stablelm_12b", "whisper_tiny",
    "mixtral_8x7b", "gemma2_9b", "codeqwen1_5_7b", "llama_3_2_vision_90b",
    "recurrentgemma_2b", "grok_1_314b", "quarl_atari",
]

_loaded = False


def _ensure_loaded():
    global _loaded
    if _loaded:
        return
    _loaded = True
    import importlib
    for mod in _ARCH_MODULES:
        try:
            importlib.import_module(f"repro.configs.{mod}")
        except ModuleNotFoundError:  # pragma: no cover - during bring-up
            pass
